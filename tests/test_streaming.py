"""Streaming engine API: event lifecycle, handles, cancellation,
preemption + bit-exact resume, EDF/SLO admission, and the router.

The LM side runs a tiny dense config through the real paged runtime,
so block accounting (``check_consistency``, pool byte baselines) is
exercised for every cancel/preempt path.  Preempt-resume bit-equality
runs on the decode-step-scan prefill path (``fused_prefill=False``),
which is bit-identical to decode by the PR 2/3 oracle tests.
"""
import jax
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.engine import (TINY_SD, Admitted, Cancelled, CostModel,
                          DiffusionEngine, EngineRouter, EventBus, Finished,
                          GenerateRequest, Preempted, PreviewLatent, Progress,
                          Rejected, TokenDelta, calibrate, init_pipeline)
from repro.models.transformer import init_lm
from repro.serving import ContinuousBatcher, Request

pytestmark = pytest.mark.serving

CFG = ModelConfig(name="t", family="dense", num_layers=2, d_model=64,
                  num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=96,
                  head_dim=16)


@pytest.fixture(scope="module")
def params():
    return init_lm(jax.random.PRNGKey(0), CFG)


@pytest.fixture(scope="module")
def sd_params():
    return init_pipeline(jax.random.PRNGKey(0), TINY_SD)


def _prompt(seed, n):
    rng = np.random.default_rng(seed)
    return [int(t) for t in rng.integers(1, 90, n)]


def _mk(params, **kw):
    kw.setdefault("slots", 2)
    kw.setdefault("max_len", 32)
    return ContinuousBatcher(params, CFG, **kw)


def _events_for(cb, rid):
    return [e for e in cb.bus.log if e.rid == rid]


# ------------------------------------------------------------ lifecycle
class TestEventLifecycle:
    def test_handle_events_drive_engine_to_terminal(self, params):
        cb = _mk(params)
        h = cb.submit(Request(rid=0, prompt=_prompt(0, 5), max_new=4))
        assert h.state == "QUEUED"
        evs = list(h.events())
        assert isinstance(evs[0], Admitted)
        assert isinstance(evs[-1], Finished)
        assert h.state == "FINISHED" and h.done
        toks = [e for e in evs if isinstance(e, TokenDelta)]
        assert [t.pos for t in toks] == list(range(4))
        assert [t.token for t in toks] == evs[-1].result.out

    def test_result_matches_run(self, params):
        cb = _mk(params)
        h = cb.submit(Request(rid=0, prompt=_prompt(1, 5), max_new=4))
        res = h.result()
        assert res.outcome == "finished" and res.finished
        assert res.stats.decode_steps == res.request.decode_steps
        cb2 = _mk(params)
        cb2.submit(Request(rid=0, prompt=_prompt(1, 5), max_new=4))
        assert list(res.tokens) == cb2.run()[0].out

    def test_bus_refuses_events_after_terminal(self):
        bus = EventBus()
        bus.emit(Finished, 0, result=None)
        with pytest.raises(RuntimeError, match="after terminal"):
            bus.emit(TokenDelta, 0, token=1, pos=0)

    def test_bus_refuses_duplicate_admission(self):
        bus = EventBus()
        bus.emit(Admitted, 0, slot=0)
        with pytest.raises(RuntimeError, match="duplicate Admitted"):
            bus.emit(Admitted, 0, slot=1)

    def test_stream_yields_every_event_once_in_order(self, params):
        cb = _mk(params)
        for rid in range(3):
            cb.submit(Request(rid=rid, prompt=_prompt(rid, 4), max_new=3))
        seen = list(cb.stream())
        assert [e.seq for e in seen] == sorted(e.seq for e in seen)
        assert len(seen) == len(cb.bus.log)
        assert sum(isinstance(e, Finished) for e in seen) == 3


# --------------------------------------------------------- cancellation
class TestCancel:
    def test_cancel_while_queued(self, params):
        cb = _mk(params, slots=1)
        cb.submit(Request(rid=0, prompt=_prompt(0, 4), max_new=3))
        h = cb.submit(Request(rid=1, prompt=_prompt(1, 4), max_new=3))
        assert h.cancel()
        done = cb.run()
        assert [r.rid for r in done] == [0]
        assert h.state == "CANCELLED"
        assert not any(isinstance(e, Admitted)
                       for e in _events_for(cb, 1))
        assert cb.runtime.allocated_blocks == 0

    def test_cancel_mid_prefill_frees_blocks(self, params):
        cb = _mk(params, slots=1, prefill_chunk=2, fused_prefill=False)
        h = cb.submit(Request(rid=0, prompt=_prompt(2, 9), max_new=4))
        cb.step()                       # one prefill chunk only
        assert cb.slots[0] is not None and cb._pending[0]
        assert cb.runtime.allocated_blocks > 0
        assert h.cancel()
        cb.runtime.check_consistency()
        assert cb.runtime.allocated_blocks == 0
        assert cb.slots[0] is None and not cb.has_work()
        assert isinstance(_events_for(cb, 0)[-1], Cancelled)

    def test_cancel_mid_decode_frees_blocks_and_pool_bytes(self, params):
        cb = _mk(params, slots=2)
        cb.submit(Request(rid=0, prompt=_prompt(3, 5), max_new=10))
        cb.submit(Request(rid=1, prompt=_prompt(4, 5), max_new=10))
        while not any(r is not None and len(r.out) >= 2
                      for r in cb.slots):
            cb.step()
        before = cb.runtime.allocated_blocks
        assert cb.cancel(0)
        cb.runtime.check_consistency()
        assert cb.runtime.allocated_blocks < before
        done = cb.run()
        assert [r.rid for r in done] == [1]
        assert cb.runtime.allocated_blocks == 0   # pool back to baseline
        # no events for rid 0 after its Cancelled
        evs = _events_for(cb, 0)
        assert isinstance(evs[-1], Cancelled)

    def test_cancel_unknown_rid_is_false(self, params):
        cb = _mk(params)
        assert not cb.cancel(99)

    def test_duplicate_rid_rejected_at_submit(self, params):
        """Reused rids fail fast at submit (queued, running, or
        finished), not later inside step() against bus invariants."""
        cb = _mk(params)
        cb.submit(Request(rid=0, prompt=[1, 2], max_new=2))
        with pytest.raises(ValueError, match="duplicate rid"):
            cb.submit(Request(rid=0, prompt=[3, 4], max_new=2))  # queued
        cb.run()
        with pytest.raises(ValueError, match="duplicate rid"):
            cb.submit(Request(rid=0, prompt=[5, 6], max_new=2))  # done

    def test_cancelled_slot_is_reusable(self, params):
        """A freed slot admits the next queued request in the same
        wave and every wave stays bit-exact."""
        cb = _mk(params, slots=1)
        solo = _mk(params, slots=1)
        solo.submit(Request(rid=7, prompt=_prompt(5, 6), max_new=5))
        expect = solo.run()[0].out
        cb.submit(Request(rid=0, prompt=_prompt(6, 6), max_new=8))
        cb.submit(Request(rid=1, prompt=_prompt(5, 6), max_new=5))
        while cb.slots[0] is None or len(cb.slots[0].out) < 1:
            cb.step()
        cb.cancel(0)
        done = cb.run()
        assert [r.rid for r in done] == [1]
        assert done[0].out == expect


# ----------------------------------------------------------- preemption
class TestPreemption:
    def test_preempt_resume_bit_identical(self, params):
        ref = _mk(params, slots=1, fused_prefill=False)
        ref.submit(Request(rid=0, prompt=_prompt(8, 6), max_new=10))
        expect = ref.run()[0].out

        cb = _mk(params, slots=1, fused_prefill=False)
        h = cb.submit(Request(rid=0, prompt=_prompt(8, 6), max_new=10))
        while len(cb.slots[0].out if cb.slots[0] else []) < 4:
            cb.step()
        assert cb.preempt(0)
        assert cb.runtime.allocated_blocks == 0   # blocks released
        assert h.state == "PREEMPTED"
        out = cb.run()[0].out
        assert out == expect                      # bit-identical resume
        # lifecycle: one Admitted, one Preempted, one resume Progress,
        # strictly increasing token positions across the interruption
        evs = _events_for(cb, 0)
        assert sum(isinstance(e, Admitted) for e in evs) == 1
        assert sum(isinstance(e, Preempted) for e in evs) == 1
        assert any(isinstance(e, Progress) and e.phase == "resume"
                   for e in evs)
        poss = [e.pos for e in evs if isinstance(e, TokenDelta)]
        assert poss == list(range(10))

    def test_preempt_counts_prefill_requeue_cost(self, params):
        """Resume re-ingests prompt + generated tokens through chunked
        prefill (no decode quanta replay)."""
        cb = _mk(params, slots=1, prefill_chunk=4, fused_prefill=False)
        cb.submit(Request(rid=0, prompt=_prompt(9, 6), max_new=8))
        while len(cb.slots[0].out if cb.slots[0] else []) < 3:
            cb.step()
        q0 = cb.prefill_quanta
        cb.preempt(0)
        (req,) = cb.run()
        assert req.out and len(req.out) == 8
        assert cb.prefill_quanta > q0      # resume paid prefill quanta

    def test_auto_preempt_over_budget(self, params):
        """A decode that outlived its deadline is evicted when a
        feasible request waits; both finish."""
        box = {}

        def vclock():
            cb = box.get("cb")
            return 0.0 if cb is None else \
                (cb.prefill_quanta + cb.decode_quanta) * 0.01

        cb = _mk(params, slots=1, clock=vclock, fused_prefill=False,
                 preempt_over_budget=True)
        box["cb"] = cb
        cb.submit(Request(rid=0, prompt=_prompt(10, 4), max_new=12,
                          deadline_ms=20.0))    # expires after 2 quanta
        h = cb.submit(Request(rid=1, prompt=_prompt(11, 4), max_new=2,
                              deadline_ms=10_000.0))
        done = {r.rid: r for r in cb.run()}
        assert set(done) == {0, 1}
        assert cb.preemptions >= 1
        assert any(isinstance(e, Preempted) for e in _events_for(cb, 0))
        # the feasible waiter got the slot and met its SLO
        fin1 = next(e for e in _events_for(cb, 1)
                    if isinstance(e, Finished))
        assert fin1.ts <= 10.0
        assert h.state == "FINISHED"

    def test_no_preemption_under_fifo_admission(self, params):
        """preempt_over_budget requires EDF: under the pure-FIFO pop
        the victim would instantly reclaim its slot (churn), so the
        scheduler must not preempt at all with edf=False."""
        box = {}

        def vclock():
            cb = box.get("cb")
            return 0.0 if cb is None else \
                (cb.prefill_quanta + cb.decode_quanta) * 0.01

        cb = _mk(params, slots=1, clock=vclock, fused_prefill=False,
                 edf=False, preempt_over_budget=True)
        box["cb"] = cb
        cb.submit(Request(rid=0, prompt=_prompt(10, 4), max_new=12,
                          deadline_ms=20.0))
        cb.submit(Request(rid=1, prompt=_prompt(11, 4), max_new=2,
                          deadline_ms=10_000.0))
        done = {r.rid for r in cb.run()}
        assert done == {0, 1}
        assert cb.preemptions == 0


# ----------------------------------------------------- EDF / SLO policy
class TestEDF:
    def _hit_rate(self, params, edf):
        box = {}

        def vclock():
            cb = box.get("cb")
            return 0.0 if cb is None else \
                (cb.prefill_quanta + cb.decode_quanta) * 0.01

        cb = _mk(params, slots=1, max_len=16, edf=edf, clock=vclock,
                 fused_prefill=False)
        box["cb"] = cb
        deadlines = [2000.0, 1000.0, 300.0, 150.0]
        for rid, dl in enumerate(deadlines):
            cb.submit(Request(rid=rid, prompt=[1, 2, 3], max_new=4,
                              deadline_ms=dl))
        fins = {e.rid: e.ts for e in cb.stream()
                if isinstance(e, Finished)}
        return sum(fins[r] <= deadlines[r] / 1e3
                   for r in fins) / len(fins)

    def test_edf_strictly_beats_fifo(self, params):
        assert self._hit_rate(params, True) > self._hit_rate(params,
                                                             False)

    def test_no_deadlines_is_exact_fifo(self, params):
        """EDF with no deadlines must reproduce FIFO admission order
        bit-exactly (the run()-compatibility guarantee)."""
        outs = []
        for edf in (True, False):
            cb = _mk(params, slots=1, edf=edf)
            for rid in range(4):
                cb.submit(Request(rid=rid, prompt=_prompt(rid, 4),
                                  max_new=3))
            outs.append([(r.rid, tuple(r.out)) for r in cb.run()])
        assert outs[0] == outs[1]

    def test_expired_requests_sort_behind_feasible(self, params):
        box = {}

        def vclock():
            cb = box.get("cb")
            return 0.0 if cb is None else \
                (cb.prefill_quanta + cb.decode_quanta) * 0.05

        cb = _mk(params, slots=1, clock=vclock, fused_prefill=False)
        box["cb"] = cb
        # rid 0 occupies the slot and burns past rid 1's deadline
        # while rid 1 waits; rid 2 (feasible) must then be admitted
        # before rid 1 (expired).
        cb.submit(Request(rid=0, prompt=[1, 2], max_new=10))
        cb.run(max_steps=2)             # rid 0 holds the slot
        cb.submit(Request(rid=1, prompt=[3, 4], max_new=2,
                          deadline_ms=1.0))
        cb.run(max_steps=2)             # rid 1's deadline now expired
        cb.submit(Request(rid=2, prompt=[5, 6], max_new=2,
                          deadline_ms=10_000.0))
        order = [e.rid for e in cb.stream() if isinstance(e, Admitted)]
        assert order.index(2) < order.index(1)

    def test_priority_breaks_deadline_ties(self, params):
        cb = _mk(params, slots=1)
        cb.submit(Request(rid=0, prompt=[1, 2], max_new=2))  # occupies
        cb.submit(Request(rid=1, prompt=[3, 4], max_new=2, priority=0))
        cb.submit(Request(rid=2, prompt=[5, 6], max_new=2, priority=5))
        order = [e.rid for e in cb.stream() if isinstance(e, Admitted)]
        assert order.index(2) < order.index(1)

    def test_group_fairness_survives_edf(self, params):
        """Round-robin across fairness groups still outranks EDF: a
        tight deadline in group 0 cannot starve group 1's turn."""
        cb = _mk(params, slots=1)
        cb.submit(Request(rid=0, prompt=[1, 2], max_new=2, group=0))
        cb.submit(Request(rid=1, prompt=[3, 4], max_new=2, group=0,
                          deadline_ms=1e6))
        cb.submit(Request(rid=2, prompt=[5, 6], max_new=2, group=1))
        order = [e.rid for e in cb.stream() if isinstance(e, Admitted)]
        # within g0 EDF picks rid 1 (has a deadline) over rid 0, but
        # the group rotation g0 -> g1 -> g0 is untouched: rid 2 goes
        # second even though g0 still holds an earlier deadline.
        assert order == [1, 2, 0]


# ----------------------------------------- cost model / admission ctrl
def _vclock_cb(params, box, **kw):
    """Batcher on a virtual clock: 1 scheduling quantum == 10 ms."""
    def vclock():
        cb = box.get("cb")
        return 0.0 if cb is None else \
            (cb.prefill_quanta + cb.decode_quanta) * 0.01

    kw.setdefault("slots", 1)
    kw.setdefault("max_len", 32)
    kw.setdefault("fused_prefill", False)
    cb = ContinuousBatcher(params, CFG, clock=vclock, **kw)
    box["cb"] = cb
    return cb


def _calibrated_cb(params, box, **kw):
    cb = _vclock_cb(params, box, cost_model=CostModel(), **kw)
    calibrate(cb, [Request(rid=900 + i, prompt=[1, 2, 3], max_new=4)
                   for i in range(2)])
    return cb


class TestCostModel:
    def test_ewma_observe_and_seed(self):
        cm = CostModel(alpha=0.5)
        assert cm.cost(("k",)) is None
        cm.seed(("k",), 1.0)
        assert cm.cost(("k",)) == 1.0
        cm.observe(("k",), 2.0)        # 0.5*1.0 + 0.5*2.0
        assert cm.cost(("k",)) == pytest.approx(1.5)
        cm2 = CostModel()
        cm2.observe(("k",), 3.0)       # first observation sets outright
        assert cm2.cost(("k",)) == pytest.approx(3.0)

    def test_calibration_seeds_lm_phases(self, params):
        box = {}
        cb = _calibrated_cb(params, box)
        kp, kd = cb.cost_model.lm_keys(cb)
        # virtual clock: every quantum is exactly 10 ms
        assert cb.cost_model.cost(kp) == pytest.approx(0.01)
        assert cb.cost_model.cost(kd) == pytest.approx(0.01)
        # prompt 3 (1 chunk) + 3 decode quanta = 40 ms
        est = cb.cost_model.estimate_lm(
            cb, Request(rid=99, prompt=[1, 2, 3], max_new=4))
        assert est == pytest.approx(0.04)

    def test_estimate_none_when_unseeded(self, params):
        box = {}
        cb = _vclock_cb(params, box, cost_model=CostModel())
        est = cb.cost_model.estimate_lm(
            cb, Request(rid=0, prompt=[1, 2, 3], max_new=4))
        assert est is None
        # unseeded model admits optimistically: nothing rejected
        cb.submit(Request(rid=0, prompt=[1, 2, 3], max_new=4,
                          deadline_ms=1.0))
        assert cb.queue_len == 1 and cb.rejections == 0


class TestRejectedLifecycle:
    def test_reject_at_submit_single_terminal_no_admitted(self, params):
        box = {}
        cb = _calibrated_cb(params, box)
        base_blocks = cb.runtime.allocated_blocks
        h = cb.submit(Request(rid=0, prompt=[1, 2, 3], max_new=4,
                              deadline_ms=30.0))   # est 40 ms > 30 ms
        evs = _events_for(cb, 0)
        assert len(evs) == 1 and isinstance(evs[0], Rejected)
        assert evs[0].estimated_s == pytest.approx(0.04)
        assert evs[0].budget_s == pytest.approx(0.03)
        assert evs[0].reason == "infeasible"
        assert not cb.bus.admitted(0)
        assert h.state == "REJECTED" and h.done
        # queue/slot/KV accounting untouched by the rejection
        assert cb.queue_len == 0
        assert all(s is None for s in cb.slots)
        assert cb.runtime.allocated_blocks == base_blocks
        cb.runtime.check_consistency()
        assert cb.rejections == 1

    def test_result_and_run_for_rejected(self, params):
        """Contract choice (documented in engine/README.md): a
        rejected request's ``handle.result()`` is a typed terminal
        with ``outcome="rejected"`` and the scheduler's reason — and
        ``run()`` simply never yields it; neither raises."""
        box = {}
        cb = _calibrated_cb(params, box)
        h = cb.submit(Request(rid=0, prompt=[1, 2, 3], max_new=4,
                              deadline_ms=30.0))
        cb.submit(Request(rid=1, prompt=[1, 2, 3], max_new=4))
        res = h.result()
        assert res.outcome == "rejected" and not res.finished
        assert res.reason == "infeasible"
        done = cb.run()
        assert [r.rid for r in done if r.rid < 900] == [1]
        # events() replays the single terminal and stops cleanly
        assert [type(e) for e in h.events()] == [Rejected]

    def test_rejected_rid_cannot_be_reused(self, params):
        box = {}
        cb = _calibrated_cb(params, box)
        cb.submit(Request(rid=0, prompt=[1, 2, 3], max_new=4,
                          deadline_ms=30.0))
        with pytest.raises(ValueError, match="duplicate rid"):
            cb.submit(Request(rid=0, prompt=[1, 2, 3], max_new=4))

    def test_no_deadline_never_rejected(self, params):
        box = {}
        cb = _calibrated_cb(params, box)
        cb.submit(Request(rid=0, prompt=[1, 2, 3], max_new=4))
        assert cb.rejections == 0 and cb.queue_len == 1
        assert cb.run()[-1].rid == 0

    def test_diffusion_reject_at_submit(self, sd_params):
        cm = CostModel()
        cm.seed(("diff", TINY_SD.name, "clip", False, 1, None), 0.01)
        cm.seed(("diff", TINY_SD.name, "unet_step", "ddim", 8, False, 1,
                 None),
                0.02)
        cm.seed(("diff", TINY_SD.name, "vae", 8, 1, None), 0.01)
        eng = DiffusionEngine(sd_params, TINY_SD, max_batch=1,
                              cost_model=cm)
        toks = [1] * TINY_SD.text_len
        # ddim-4 pads to a pow2 scan of 4: 10+4*20+10 = 100 ms est
        h = eng.submit(GenerateRequest(rid=0, tokens=toks, sampler="ddim",
                                       steps=4, seed=0, deadline_ms=60.0))
        assert h.state == "REJECTED"
        assert h.result().outcome == "rejected"
        assert not eng.queue and eng.traces == 0   # nothing ran
        evs = [e for e in eng.bus.log if e.rid == 0]
        assert len(evs) == 1 and isinstance(evs[0], Rejected)
        with pytest.raises(ValueError, match="duplicate rid"):
            eng.submit(GenerateRequest(rid=0, tokens=toks, steps=1))

    def test_stale_queued_requests_swept_to_rejected(self, params):
        """The queue-bloat bugfix: a request that was feasible at
        submit but went stale waiting behind a long-running slot is
        swept to Rejected on step() instead of sorting behind feasible
        work while occupying queue memory forever."""
        box = {}
        cb = _calibrated_cb(params, box)
        cb.submit(Request(rid=0, prompt=[1, 2, 3], max_new=12))
        cb.run(max_steps=3)             # rid 0 occupies the slot
        cb.submit(Request(rid=1, prompt=[1, 2, 3], max_new=4,
                          deadline_ms=50.0))  # est 40 <= 50: enqueued
        assert cb.queue_len == 1
        for _ in range(4):              # slot still busy; rid 1 rots
            cb.step()
        assert cb.queue_len == 0        # swept once provably hopeless
        evs = _events_for(cb, 1)
        assert len(evs) == 1 and isinstance(evs[0], Rejected)
        assert not cb.bus.admitted(1)
        done = cb.run()
        assert [r.rid for r in done if r.rid < 900] == [0]

    def test_queue_stays_bounded_under_stale_flood(self, params):
        box = {}
        cb = _calibrated_cb(params, box)
        cb.submit(Request(rid=0, prompt=[1, 2, 3], max_new=30,
                          deadline_ms=None))
        cb.run(max_steps=3)             # slot busy for 30 quanta
        for rid in range(1, 9):
            cb.submit(Request(rid=rid, prompt=[1, 2, 3], max_new=4,
                              deadline_ms=41.0))  # feasible at submit
            cb.step()                   # ...stale one quantum later
            cb.step()
        assert cb.queue_len == 0 and cb.rejections == 8
        assert all(not cb.bus.admitted(rid) for rid in range(1, 9))

    def test_default_cost_model_none_is_bit_identical(self, params):
        """cost_model=None (every existing caller) must keep the PR 4
        behavior bit-exactly, deadlines included."""
        outs = []
        for attach in (False, True):
            box = {}
            cb = _vclock_cb(params, box,
                            cost_model=CostModel() if attach else None)
            # No calibration: the attached model stays empty, so both
            # runs admit everything; outputs must match bit-exactly.
            for rid in range(3):
                cb.submit(Request(rid=rid, prompt=_prompt(rid, 4),
                                  max_new=3, deadline_ms=1000.0))
            outs.append([(r.rid, tuple(r.out)) for r in cb.run()])
        assert outs[0] == outs[1]


class TestPredictivePreemption:
    def test_preempts_before_deadline_passes(self, params):
        """With a cost model, a decode *predicted* to overrun is
        evicted while its deadline is still in the future (the old
        check waited for the overrun to happen).  The stale-optimistic
        seed (10x too cheap, so the doomed request is admitted) is
        corrected by the online EWMA from observed quanta — exactly
        the calibration-drift case predictive eviction exists for."""
        box = {}
        cm = CostModel(alpha=0.5)
        cb = _vclock_cb(params, box, cost_model=cm,
                        preempt_over_budget=True)
        kp, kd = cm.lm_keys(cb)
        cm.seed(kp, 0.01)
        cm.seed(kd, 0.001)              # optimistic: real cost is 0.01
        # True cost: 1 prefill + 11 decode quanta = 120 ms > 60 ms
        # budget, but the stale seed prices it at ~21 ms -> admitted.
        cb.submit(Request(rid=0, prompt=[1, 2, 3], max_new=12,
                          deadline_ms=60.0))
        for _ in range(4):              # EWMA learns the real decode cost
            cb.step()
        assert cb.bus.clock() < 0.06    # deadline still in the future
        cb.submit(Request(rid=1, prompt=[1, 2, 3], max_new=2,
                          deadline_ms=10_000.0))
        done = cb.run()
        assert cb.preemptions >= 1
        assert any(isinstance(e, Preempted) for e in _events_for(cb, 0))
        # the doomed victim is rejected at its next pop, the feasible
        # waiter finishes
        assert [r.rid for r in done] == [1]
        assert isinstance(_events_for(cb, 0)[-1], Rejected)

    def test_feasible_decode_not_preempted(self, params):
        """Predictive preemption must leave a decode alone when the
        model says it will still make its deadline."""
        box = {}
        cb = _calibrated_cb(params, box, preempt_over_budget=True)
        cb.submit(Request(rid=0, prompt=[1, 2, 3], max_new=4,
                          deadline_ms=2000.0))    # comfortably feasible
        cb.run(max_steps=2)
        cb.submit(Request(rid=1, prompt=[1, 2, 3], max_new=2,
                          deadline_ms=10_000.0))
        done = cb.run()
        assert cb.preemptions == 0
        assert {r.rid for r in done if r.rid < 900} == {0, 1}


# --------------------------------------------------------------- router
class TestRouter:
    def test_interleaves_diffusion_and_lm_events(self, params,
                                                 sd_params):
        toks = [1] * TINY_SD.text_len
        diff = DiffusionEngine(sd_params, TINY_SD, max_batch=1)
        lm = _mk(params)
        router = EngineRouter(diffusion=diff, lm=lm)
        router.submit(GenerateRequest(rid=0, tokens=toks, sampler="ddim",
                                      steps=4, seed=0, preview_every=1))
        router.submit(Request(rid=1, prompt=_prompt(0, 4), max_new=5))
        log = list(router.stream())
        rids = [e.rid for e in log]
        first0 = rids.index(0)
        last0 = len(rids) - 1 - rids[::-1].index(0)
        assert any(r == 1 for r in rids[first0:last0]), \
            "no LM event between diffusion events"
        assert sum(isinstance(e, Finished) for e in log) == 2
        assert any(isinstance(e, PreviewLatent) for e in log)
        # one total order on one shared bus
        assert [e.seq for e in log] == sorted(e.seq for e in log)
        assert diff.bus is lm.bus is router.bus

    def test_handle_pumps_router_across_engines(self, params,
                                                sd_params):
        """Waiting on the diffusion handle must still finish the LM
        request (the handle pumps the router, not one engine)."""
        toks = [1] * TINY_SD.text_len
        router = EngineRouter(
            diffusion=DiffusionEngine(sd_params, TINY_SD, max_batch=1),
            lm=_mk(params))
        hd = router.submit(GenerateRequest(rid=0, tokens=toks,
                                           sampler="ddim", steps=4,
                                           seed=0, preview_every=1))
        router.submit(Request(rid=1, prompt=_prompt(1, 3), max_new=12))
        assert hd.result().outcome == "finished"
        # LM made progress while we waited on diffusion: the deadline
        # tie round-robins the router between the two engines.
        assert router.lm.prefill_quanta + router.lm.decode_quanta > 0

    def test_cancel_routes_to_owner(self, params, sd_params):
        toks = [1] * TINY_SD.text_len
        router = EngineRouter(
            diffusion=DiffusionEngine(sd_params, TINY_SD, max_batch=1),
            lm=_mk(params))
        router.submit(GenerateRequest(rid=0, tokens=toks, steps=1,
                                      seed=0))
        h = router.submit(Request(rid=1, prompt=_prompt(2, 4),
                                  max_new=4))
        assert h.cancel()
        assert router.lm.runtime.allocated_blocks == 0
        results = router.run()
        assert [r.rid for r in results] == [0]
        assert not router.cancel(42)

    def test_duplicate_rid_across_engines_rejected(self, params,
                                                   sd_params):
        toks = [1] * TINY_SD.text_len
        router = EngineRouter(
            diffusion=DiffusionEngine(sd_params, TINY_SD, max_batch=1),
            lm=_mk(params))
        router.submit(GenerateRequest(rid=0, tokens=toks, steps=1,
                                      seed=0))
        with pytest.raises(ValueError, match="duplicate rid"):
            router.submit(Request(rid=0, prompt=[1, 2], max_new=2))

    def test_edf_across_engines_prefers_tight_deadline(self, params,
                                                       sd_params):
        """The router steps the engine whose pending work has the
        earlier deadline first."""
        toks = [1] * TINY_SD.text_len
        router = EngineRouter(
            diffusion=DiffusionEngine(sd_params, TINY_SD, max_batch=1),
            lm=_mk(params))
        router.submit(GenerateRequest(rid=0, tokens=toks, steps=1,
                                      seed=0))           # no deadline
        router.submit(Request(rid=1, prompt=_prompt(3, 3), max_new=2,
                              deadline_ms=50.0))         # tight
        log = list(router.stream())
        admits = [e.rid for e in log if isinstance(e, Admitted)]
        assert admits[0] == 1           # LM's deadline won the first step

    def test_slack_outranks_raw_deadline_with_cost_models(self, params,
                                                          sd_params):
        """With cost models on both engines the router steps by
        estimated slack: a diffusion request with a *later* deadline
        but a long predicted service time outranks an earlier-deadline
        LM request that needs almost no time."""
        toks = [1] * TINY_SD.text_len
        dcm = CostModel()
        dcm.seed(("diff", TINY_SD.name, "clip", False, 1, None), 0.01)
        dcm.seed(("diff", TINY_SD.name, "unet_step", "ddim", 8, False, 1,
                 None),
                 0.5)
        dcm.seed(("diff", TINY_SD.name, "vae", 8, 1, None), 0.01)
        lcm = CostModel()
        diff = DiffusionEngine(sd_params, TINY_SD, max_batch=1,
                               cost_model=dcm)
        lm = _mk(params, cost_model=lcm)
        lcm.seed(lcm.lm_keys(lm)[0], 0.001)
        lcm.seed(lcm.lm_keys(lm)[1], 0.001)
        router = EngineRouter(diffusion=diff, lm=lm)
        # Deadlines are wall-clock here, so keep them far out (compile
        # time must not expire them); only their *order* matters.
        # diffusion: est 0.01+4*0.5+0.01 ~ 2 s, deadline 301 s
        #   -> slack ~299 s
        router.submit(GenerateRequest(rid=0, tokens=toks, sampler="ddim",
                                      steps=4, seed=0,
                                      deadline_ms=301_000.0))
        # LM: est ~4 ms, deadline 300 s (earlier!) -> slack ~300 s
        router.submit(Request(rid=1, prompt=_prompt(3, 3), max_new=2,
                              deadline_ms=300_000.0))
        log = list(router.stream())
        admits = [e.rid for e in log if isinstance(e, Admitted)]
        # raw-deadline stepping (PR 4) would admit the LM request
        # first; slack stepping starts the long diffusion job.
        assert admits[0] == 0
        assert sum(isinstance(e, Finished) for e in log) == 2

    def test_next_slack_is_min_over_engines(self, params, sd_params):
        """``router.next_slack()`` is the minimum estimated slack over
        the engines behind it — the key a FleetManager multiplexes
        replica routers on — computed on the one shared (virtual)
        clock."""
        toks = [1] * TINY_SD.text_len
        dcm, lcm = CostModel(), CostModel()
        diff = DiffusionEngine(sd_params, TINY_SD, max_batch=1,
                               cost_model=dcm, clock=lambda: 0.0)
        lm = _mk(params, cost_model=lcm, clock=lambda: 0.0)
        router = EngineRouter(diffusion=diff, lm=lm)
        dreq = GenerateRequest(rid=0, tokens=toks, sampler="ddim",
                               steps=4, seed=0, deadline_ms=5_000.0)
        dcm.seed(dcm._diff_keys(diff, dreq)["fused"], 2.0)
        kp, kd = lcm.lm_keys(lm)
        lcm.seed(kp, 0.01)
        lcm.seed(kd, 0.01)
        router.submit(dreq)
        # 1 prefill chunk + 1 decode -> est 0.02 s, slack 1 - 0.02
        router.submit(Request(rid=1, prompt=_prompt(4, 3), max_new=2,
                              deadline_ms=1_000.0))
        assert diff.next_slack() == pytest.approx(5.0 - 2.0)
        assert lm.next_slack() == pytest.approx(1.0 - 0.02)
        assert router.next_slack() == pytest.approx(
            min(diff.next_slack(), lm.next_slack()))
        # an engine with no deadline-bearing work contributes +inf
        lm.cancel(1)
        assert lm.next_slack() == float("inf")
        assert router.next_slack() == diff.next_slack()

    def test_next_slack_tie_rotates_round_robin(self, params,
                                                sd_params):
        """Deadline-free work on both engines gives identical +inf
        slack every quantum: the tie must rotate round-robin so a
        deadline-free diffusion backlog cannot starve LM decode on the
        slack path (the PR 4 guarantee, preserved under cost models)."""
        toks = [1] * TINY_SD.text_len
        diff = DiffusionEngine(sd_params, TINY_SD, max_batch=1,
                               cost_model=CostModel())
        lm = _mk(params, cost_model=CostModel())
        router = EngineRouter(diffusion=diff, lm=lm)
        router.submit(GenerateRequest(rid=0, tokens=toks, sampler="ddim",
                                      steps=6, seed=0, preview_every=1))
        router.submit(Request(rid=1, prompt=_prompt(5, 4), max_new=6))
        assert router.next_slack() == float("inf")
        order = []
        while router.has_work() and len(order) < 4:
            before = lm.prefill_quanta + lm.decode_quanta
            router.step()
            order.append("lm" if lm.prefill_quanta + lm.decode_quanta
                         > before else "diff")
        # both stayed busy for these quanta, so ties alternated 1:1
        assert order == ["diff", "lm", "diff", "lm"]
