"""Streaming engine API: event lifecycle, handles, cancellation,
preemption + bit-exact resume, EDF/SLO admission, and the router.

The LM side runs a tiny dense config through the real paged runtime,
so block accounting (``check_consistency``, pool byte baselines) is
exercised for every cancel/preempt path.  Preempt-resume bit-equality
runs on the decode-step-scan prefill path (``fused_prefill=False``),
which is bit-identical to decode by the PR 2/3 oracle tests.
"""
import jax
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.engine import (TINY_SD, Admitted, Cancelled, DiffusionEngine,
                          EngineRouter, EventBus, Finished, GenerateRequest,
                          Preempted, PreviewLatent, Progress, TokenDelta,
                          init_pipeline)
from repro.models.transformer import init_lm
from repro.serving import ContinuousBatcher, Request

pytestmark = pytest.mark.serving

CFG = ModelConfig(name="t", family="dense", num_layers=2, d_model=64,
                  num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=96,
                  head_dim=16)


@pytest.fixture(scope="module")
def params():
    return init_lm(jax.random.PRNGKey(0), CFG)


@pytest.fixture(scope="module")
def sd_params():
    return init_pipeline(jax.random.PRNGKey(0), TINY_SD)


def _prompt(seed, n):
    rng = np.random.default_rng(seed)
    return [int(t) for t in rng.integers(1, 90, n)]


def _mk(params, **kw):
    kw.setdefault("slots", 2)
    kw.setdefault("max_len", 32)
    return ContinuousBatcher(params, CFG, **kw)


def _events_for(cb, rid):
    return [e for e in cb.bus.log if e.rid == rid]


# ------------------------------------------------------------ lifecycle
class TestEventLifecycle:
    def test_handle_events_drive_engine_to_terminal(self, params):
        cb = _mk(params)
        h = cb.submit(Request(rid=0, prompt=_prompt(0, 5), max_new=4))
        assert h.state == "QUEUED"
        evs = list(h.events())
        assert isinstance(evs[0], Admitted)
        assert isinstance(evs[-1], Finished)
        assert h.state == "FINISHED" and h.done
        toks = [e for e in evs if isinstance(e, TokenDelta)]
        assert [t.pos for t in toks] == list(range(4))
        assert [t.token for t in toks] == evs[-1].result.out

    def test_result_matches_run(self, params):
        cb = _mk(params)
        h = cb.submit(Request(rid=0, prompt=_prompt(1, 5), max_new=4))
        via_handle = h.result().out
        cb2 = _mk(params)
        cb2.submit(Request(rid=0, prompt=_prompt(1, 5), max_new=4))
        assert via_handle == cb2.run()[0].out

    def test_bus_refuses_events_after_terminal(self):
        bus = EventBus()
        bus.emit(Finished, 0, result=None)
        with pytest.raises(RuntimeError, match="after terminal"):
            bus.emit(TokenDelta, 0, token=1, pos=0)

    def test_bus_refuses_duplicate_admission(self):
        bus = EventBus()
        bus.emit(Admitted, 0, slot=0)
        with pytest.raises(RuntimeError, match="duplicate Admitted"):
            bus.emit(Admitted, 0, slot=1)

    def test_stream_yields_every_event_once_in_order(self, params):
        cb = _mk(params)
        for rid in range(3):
            cb.submit(Request(rid=rid, prompt=_prompt(rid, 4), max_new=3))
        seen = list(cb.stream())
        assert [e.seq for e in seen] == sorted(e.seq for e in seen)
        assert len(seen) == len(cb.bus.log)
        assert sum(isinstance(e, Finished) for e in seen) == 3


# --------------------------------------------------------- cancellation
class TestCancel:
    def test_cancel_while_queued(self, params):
        cb = _mk(params, slots=1)
        cb.submit(Request(rid=0, prompt=_prompt(0, 4), max_new=3))
        h = cb.submit(Request(rid=1, prompt=_prompt(1, 4), max_new=3))
        assert h.cancel()
        done = cb.run()
        assert [r.rid for r in done] == [0]
        assert h.state == "CANCELLED"
        assert not any(isinstance(e, Admitted)
                       for e in _events_for(cb, 1))
        assert cb.runtime.allocated_blocks == 0

    def test_cancel_mid_prefill_frees_blocks(self, params):
        cb = _mk(params, slots=1, prefill_chunk=2, fused_prefill=False)
        h = cb.submit(Request(rid=0, prompt=_prompt(2, 9), max_new=4))
        cb.step()                       # one prefill chunk only
        assert cb.slots[0] is not None and cb._pending[0]
        assert cb.runtime.allocated_blocks > 0
        assert h.cancel()
        cb.runtime.check_consistency()
        assert cb.runtime.allocated_blocks == 0
        assert cb.slots[0] is None and not cb.has_work()
        assert isinstance(_events_for(cb, 0)[-1], Cancelled)

    def test_cancel_mid_decode_frees_blocks_and_pool_bytes(self, params):
        cb = _mk(params, slots=2)
        cb.submit(Request(rid=0, prompt=_prompt(3, 5), max_new=10))
        cb.submit(Request(rid=1, prompt=_prompt(4, 5), max_new=10))
        while not any(r is not None and len(r.out) >= 2
                      for r in cb.slots):
            cb.step()
        before = cb.runtime.allocated_blocks
        assert cb.cancel(0)
        cb.runtime.check_consistency()
        assert cb.runtime.allocated_blocks < before
        done = cb.run()
        assert [r.rid for r in done] == [1]
        assert cb.runtime.allocated_blocks == 0   # pool back to baseline
        # no events for rid 0 after its Cancelled
        evs = _events_for(cb, 0)
        assert isinstance(evs[-1], Cancelled)

    def test_cancel_unknown_rid_is_false(self, params):
        cb = _mk(params)
        assert not cb.cancel(99)

    def test_duplicate_rid_rejected_at_submit(self, params):
        """Reused rids fail fast at submit (queued, running, or
        finished), not later inside step() against bus invariants."""
        cb = _mk(params)
        cb.submit(Request(rid=0, prompt=[1, 2], max_new=2))
        with pytest.raises(ValueError, match="duplicate rid"):
            cb.submit(Request(rid=0, prompt=[3, 4], max_new=2))  # queued
        cb.run()
        with pytest.raises(ValueError, match="duplicate rid"):
            cb.submit(Request(rid=0, prompt=[5, 6], max_new=2))  # done

    def test_cancelled_slot_is_reusable(self, params):
        """A freed slot admits the next queued request in the same
        wave and every wave stays bit-exact."""
        cb = _mk(params, slots=1)
        solo = _mk(params, slots=1)
        solo.submit(Request(rid=7, prompt=_prompt(5, 6), max_new=5))
        expect = solo.run()[0].out
        cb.submit(Request(rid=0, prompt=_prompt(6, 6), max_new=8))
        cb.submit(Request(rid=1, prompt=_prompt(5, 6), max_new=5))
        while cb.slots[0] is None or len(cb.slots[0].out) < 1:
            cb.step()
        cb.cancel(0)
        done = cb.run()
        assert [r.rid for r in done] == [1]
        assert done[0].out == expect


# ----------------------------------------------------------- preemption
class TestPreemption:
    def test_preempt_resume_bit_identical(self, params):
        ref = _mk(params, slots=1, fused_prefill=False)
        ref.submit(Request(rid=0, prompt=_prompt(8, 6), max_new=10))
        expect = ref.run()[0].out

        cb = _mk(params, slots=1, fused_prefill=False)
        h = cb.submit(Request(rid=0, prompt=_prompt(8, 6), max_new=10))
        while len(cb.slots[0].out if cb.slots[0] else []) < 4:
            cb.step()
        assert cb.preempt(0)
        assert cb.runtime.allocated_blocks == 0   # blocks released
        assert h.state == "PREEMPTED"
        out = cb.run()[0].out
        assert out == expect                      # bit-identical resume
        # lifecycle: one Admitted, one Preempted, one resume Progress,
        # strictly increasing token positions across the interruption
        evs = _events_for(cb, 0)
        assert sum(isinstance(e, Admitted) for e in evs) == 1
        assert sum(isinstance(e, Preempted) for e in evs) == 1
        assert any(isinstance(e, Progress) and e.phase == "resume"
                   for e in evs)
        poss = [e.pos for e in evs if isinstance(e, TokenDelta)]
        assert poss == list(range(10))

    def test_preempt_counts_prefill_requeue_cost(self, params):
        """Resume re-ingests prompt + generated tokens through chunked
        prefill (no decode quanta replay)."""
        cb = _mk(params, slots=1, prefill_chunk=4, fused_prefill=False)
        cb.submit(Request(rid=0, prompt=_prompt(9, 6), max_new=8))
        while len(cb.slots[0].out if cb.slots[0] else []) < 3:
            cb.step()
        q0 = cb.prefill_quanta
        cb.preempt(0)
        (req,) = cb.run()
        assert req.out and len(req.out) == 8
        assert cb.prefill_quanta > q0      # resume paid prefill quanta

    def test_auto_preempt_over_budget(self, params):
        """A decode that outlived its deadline is evicted when a
        feasible request waits; both finish."""
        box = {}

        def vclock():
            cb = box.get("cb")
            return 0.0 if cb is None else \
                (cb.prefill_quanta + cb.decode_quanta) * 0.01

        cb = _mk(params, slots=1, clock=vclock, fused_prefill=False,
                 preempt_over_budget=True)
        box["cb"] = cb
        cb.submit(Request(rid=0, prompt=_prompt(10, 4), max_new=12,
                          deadline_ms=20.0))    # expires after 2 quanta
        h = cb.submit(Request(rid=1, prompt=_prompt(11, 4), max_new=2,
                              deadline_ms=10_000.0))
        done = {r.rid: r for r in cb.run()}
        assert set(done) == {0, 1}
        assert cb.preemptions >= 1
        assert any(isinstance(e, Preempted) for e in _events_for(cb, 0))
        # the feasible waiter got the slot and met its SLO
        fin1 = next(e for e in _events_for(cb, 1)
                    if isinstance(e, Finished))
        assert fin1.ts <= 10.0
        assert h.state == "FINISHED"

    def test_no_preemption_under_fifo_admission(self, params):
        """preempt_over_budget requires EDF: under the pure-FIFO pop
        the victim would instantly reclaim its slot (churn), so the
        scheduler must not preempt at all with edf=False."""
        box = {}

        def vclock():
            cb = box.get("cb")
            return 0.0 if cb is None else \
                (cb.prefill_quanta + cb.decode_quanta) * 0.01

        cb = _mk(params, slots=1, clock=vclock, fused_prefill=False,
                 edf=False, preempt_over_budget=True)
        box["cb"] = cb
        cb.submit(Request(rid=0, prompt=_prompt(10, 4), max_new=12,
                          deadline_ms=20.0))
        cb.submit(Request(rid=1, prompt=_prompt(11, 4), max_new=2,
                          deadline_ms=10_000.0))
        done = {r.rid for r in cb.run()}
        assert done == {0, 1}
        assert cb.preemptions == 0


# ----------------------------------------------------- EDF / SLO policy
class TestEDF:
    def _hit_rate(self, params, edf):
        box = {}

        def vclock():
            cb = box.get("cb")
            return 0.0 if cb is None else \
                (cb.prefill_quanta + cb.decode_quanta) * 0.01

        cb = _mk(params, slots=1, max_len=16, edf=edf, clock=vclock,
                 fused_prefill=False)
        box["cb"] = cb
        deadlines = [2000.0, 1000.0, 300.0, 150.0]
        for rid, dl in enumerate(deadlines):
            cb.submit(Request(rid=rid, prompt=[1, 2, 3], max_new=4,
                              deadline_ms=dl))
        fins = {e.rid: e.ts for e in cb.stream()
                if isinstance(e, Finished)}
        return sum(fins[r] <= deadlines[r] / 1e3
                   for r in fins) / len(fins)

    def test_edf_strictly_beats_fifo(self, params):
        assert self._hit_rate(params, True) > self._hit_rate(params,
                                                             False)

    def test_no_deadlines_is_exact_fifo(self, params):
        """EDF with no deadlines must reproduce FIFO admission order
        bit-exactly (the run()-compatibility guarantee)."""
        outs = []
        for edf in (True, False):
            cb = _mk(params, slots=1, edf=edf)
            for rid in range(4):
                cb.submit(Request(rid=rid, prompt=_prompt(rid, 4),
                                  max_new=3))
            outs.append([(r.rid, tuple(r.out)) for r in cb.run()])
        assert outs[0] == outs[1]

    def test_expired_requests_sort_behind_feasible(self, params):
        box = {}

        def vclock():
            cb = box.get("cb")
            return 0.0 if cb is None else \
                (cb.prefill_quanta + cb.decode_quanta) * 0.05

        cb = _mk(params, slots=1, clock=vclock, fused_prefill=False)
        box["cb"] = cb
        # rid 0 occupies the slot and burns past rid 1's deadline
        # while rid 1 waits; rid 2 (feasible) must then be admitted
        # before rid 1 (expired).
        cb.submit(Request(rid=0, prompt=[1, 2], max_new=10))
        cb.run(max_steps=2)             # rid 0 holds the slot
        cb.submit(Request(rid=1, prompt=[3, 4], max_new=2,
                          deadline_ms=1.0))
        cb.run(max_steps=2)             # rid 1's deadline now expired
        cb.submit(Request(rid=2, prompt=[5, 6], max_new=2,
                          deadline_ms=10_000.0))
        order = [e.rid for e in cb.stream() if isinstance(e, Admitted)]
        assert order.index(2) < order.index(1)

    def test_priority_breaks_deadline_ties(self, params):
        cb = _mk(params, slots=1)
        cb.submit(Request(rid=0, prompt=[1, 2], max_new=2))  # occupies
        cb.submit(Request(rid=1, prompt=[3, 4], max_new=2, priority=0))
        cb.submit(Request(rid=2, prompt=[5, 6], max_new=2, priority=5))
        order = [e.rid for e in cb.stream() if isinstance(e, Admitted)]
        assert order.index(2) < order.index(1)

    def test_group_fairness_survives_edf(self, params):
        """Round-robin across fairness groups still outranks EDF: a
        tight deadline in group 0 cannot starve group 1's turn."""
        cb = _mk(params, slots=1)
        cb.submit(Request(rid=0, prompt=[1, 2], max_new=2, group=0))
        cb.submit(Request(rid=1, prompt=[3, 4], max_new=2, group=0,
                          deadline_ms=1e6))
        cb.submit(Request(rid=2, prompt=[5, 6], max_new=2, group=1))
        order = [e.rid for e in cb.stream() if isinstance(e, Admitted)]
        # within g0 EDF picks rid 1 (has a deadline) over rid 0, but
        # the group rotation g0 -> g1 -> g0 is untouched: rid 2 goes
        # second even though g0 still holds an earlier deadline.
        assert order == [1, 2, 0]


# --------------------------------------------------------------- router
class TestRouter:
    def test_interleaves_diffusion_and_lm_events(self, params,
                                                 sd_params):
        toks = [1] * TINY_SD.text_len
        diff = DiffusionEngine(sd_params, TINY_SD, max_batch=1)
        lm = _mk(params)
        router = EngineRouter(diffusion=diff, lm=lm)
        router.submit(GenerateRequest(rid=0, tokens=toks, sampler="ddim",
                                      steps=4, seed=0, preview_every=1))
        router.submit(Request(rid=1, prompt=_prompt(0, 4), max_new=5))
        log = list(router.stream())
        rids = [e.rid for e in log]
        first0 = rids.index(0)
        last0 = len(rids) - 1 - rids[::-1].index(0)
        assert any(r == 1 for r in rids[first0:last0]), \
            "no LM event between diffusion events"
        assert sum(isinstance(e, Finished) for e in log) == 2
        assert any(isinstance(e, PreviewLatent) for e in log)
        # one total order on one shared bus
        assert [e.seq for e in log] == sorted(e.seq for e in log)
        assert diff.bus is lm.bus is router.bus

    def test_handle_pumps_router_across_engines(self, params,
                                                sd_params):
        """Waiting on the diffusion handle must still finish the LM
        request (the handle pumps the router, not one engine)."""
        toks = [1] * TINY_SD.text_len
        router = EngineRouter(
            diffusion=DiffusionEngine(sd_params, TINY_SD, max_batch=1),
            lm=_mk(params))
        hd = router.submit(GenerateRequest(rid=0, tokens=toks,
                                           sampler="ddim", steps=4,
                                           seed=0, preview_every=1))
        router.submit(Request(rid=1, prompt=_prompt(1, 3), max_new=12))
        assert hd.result() is not None
        # LM made progress while we waited on diffusion: the deadline
        # tie round-robins the router between the two engines.
        assert router.lm.prefill_quanta + router.lm.decode_quanta > 0

    def test_cancel_routes_to_owner(self, params, sd_params):
        toks = [1] * TINY_SD.text_len
        router = EngineRouter(
            diffusion=DiffusionEngine(sd_params, TINY_SD, max_batch=1),
            lm=_mk(params))
        router.submit(GenerateRequest(rid=0, tokens=toks, steps=1,
                                      seed=0))
        h = router.submit(Request(rid=1, prompt=_prompt(2, 4),
                                  max_new=4))
        assert h.cancel()
        assert router.lm.runtime.allocated_blocks == 0
        results = router.run()
        assert [r.rid for r in results] == [0]
        assert not router.cancel(42)

    def test_duplicate_rid_across_engines_rejected(self, params,
                                                   sd_params):
        toks = [1] * TINY_SD.text_len
        router = EngineRouter(
            diffusion=DiffusionEngine(sd_params, TINY_SD, max_batch=1),
            lm=_mk(params))
        router.submit(GenerateRequest(rid=0, tokens=toks, steps=1,
                                      seed=0))
        with pytest.raises(ValueError, match="duplicate rid"):
            router.submit(Request(rid=0, prompt=[1, 2], max_new=2))

    def test_edf_across_engines_prefers_tight_deadline(self, params,
                                                       sd_params):
        """The router steps the engine whose pending work has the
        earlier deadline first."""
        toks = [1] * TINY_SD.text_len
        router = EngineRouter(
            diffusion=DiffusionEngine(sd_params, TINY_SD, max_batch=1),
            lm=_mk(params))
        router.submit(GenerateRequest(rid=0, tokens=toks, steps=1,
                                      seed=0))           # no deadline
        router.submit(Request(rid=1, prompt=_prompt(3, 3), max_new=2,
                              deadline_ms=50.0))         # tight
        log = list(router.stream())
        admits = [e.rid for e in log if isinstance(e, Admitted)]
        assert admits[0] == 1           # LM's deadline won the first step
