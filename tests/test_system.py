"""End-to-end behaviour tests: train-to-learn, serve, quantized serve."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, TrainConfig
from repro.core.policy import get_policy
from repro.core.qlinear import quantize_params
from repro.data.pipeline import TokenPipeline
from repro.models.transformer import init_lm, lm_forward
from repro.train.serve_step import greedy_generate, make_cache, make_decode
from repro.train.train_step import init_train_state, make_train_step

CFG = ModelConfig(name="sys", family="dense", num_layers=2, d_model=64,
                  num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=128,
                  head_dim=16)


def test_training_reduces_loss_on_stream():
    tcfg = TrainConfig(lr=1e-3)
    params, opt, comp = init_train_state(jax.random.PRNGKey(0), CFG,
                                         tcfg, init_lm)
    step = jax.jit(make_train_step(CFG, tcfg))
    pipe = TokenPipeline(vocab_size=CFG.vocab_size, seq_len=32, batch=4,
                         seed=0)
    losses = []
    for _ in range(30):
        b = {k: jnp.asarray(v) for k, v in next(pipe).items()}
        params, opt, comp, m = step(params, opt, comp, b)
        losses.append(float(m["loss"]))
    pipe.close()
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.1, losses[:3]


def test_greedy_generation_deterministic():
    params = init_lm(jax.random.PRNGKey(1), CFG)
    prompt = jax.random.randint(jax.random.PRNGKey(2), (2, 8), 0, 128)
    s1 = greedy_generate(params, CFG, prompt, steps=8)
    s2 = greedy_generate(params, CFG, prompt, steps=8)
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
    assert s1.shape == (2, 16)
    np.testing.assert_array_equal(np.asarray(s1[:, :8]),
                                  np.asarray(prompt))


def test_quantized_serve_matches_dense_mostly():
    """Q8_0-quantized decoding should agree with dense decoding on most
    greedy tokens (the paper's quality-preservation premise)."""
    params = init_lm(jax.random.PRNGKey(3), CFG)
    qparams = quantize_params(params, get_policy("q8_0"))
    prompt = jax.random.randint(jax.random.PRNGKey(4), (1, 8), 0, 128)
    s_d = np.asarray(greedy_generate(params, CFG, prompt, steps=12))
    s_q = np.asarray(greedy_generate(qparams, CFG, prompt, steps=12))
    agree = (s_d == s_q).mean()
    # Tiny 64-dim model: quantization perturbs more than at real widths.
    assert agree > 0.5, agree


def test_decode_cache_donation_shape_stability():
    params = init_lm(jax.random.PRNGKey(5), CFG)
    cache = make_cache(params, CFG, 2, 16)
    decode = jax.jit(make_decode(CFG), donate_argnums=(3,))
    tok = jnp.zeros((2, 1), jnp.int32)
    for t in range(4):
        nxt, logits, cache = decode(params, tok, jnp.int32(t), cache)
        tok = nxt
    assert logits.shape == (2, 1, CFG.vocab_size)
