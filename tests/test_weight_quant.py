"""Opt-in quantized-weight serving (``weight_quant=``) for both engines.

The gating contract (ISSUE 8 tentpole (b)): on CPU the quantized matmul
routes through the dequant reference (``ref.q8_matmul_ref``), which is
*the same arithmetic* as the dense path applied to pre-dequantized bf16
weights — so a ``weight_quant="q8_0"`` batcher must emit tokens
bit-identical to a plain batcher given the dequantized weights.  That
pins the quantized path's correctness at dequant-reference precision;
kernel-vs-reference precision is covered by the quantized-matmul kernel
suites.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.core import quant
from repro.core.qlinear import Linear
from repro.engine import (TINY_SD, DiffusionEngine, GenerateRequest,
                          init_pipeline)
from repro.engine.costmodel import CostModel
from repro.models.transformer import init_lm
from repro.serving import ContinuousBatcher, Request

pytestmark = pytest.mark.serving

CFG = ModelConfig(name="t", family="dense", num_layers=2, d_model=64,
                  num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=96,
                  head_dim=32)


@pytest.fixture(scope="module")
def params():
    return init_lm(jax.random.PRNGKey(0), CFG)


def _prompt(seed, n):
    rng = np.random.default_rng(seed)
    return [int(t) for t in rng.integers(1, 90, n)]


def _dequantize_linears(params):
    """The dequant reference weights: every quantized Linear replaced
    by its bf16-dequantized dense twin (what ref.q8_matmul_ref and
    layers.apply_embedding decode on the fly)."""
    def deq(node):
        if isinstance(node, Linear) and not hasattr(node.w, "dtype"):
            return Linear(quant.dequantize(node.w, jnp.bfloat16),
                          node.b, node.role)
        return node
    return jax.tree.map(deq, params,
                        is_leaf=lambda x: isinstance(x, Linear))


def _run(params_or_none, cfg, prompts, **kw):
    cb = ContinuousBatcher(params_or_none, cfg, slots=2, max_len=24,
                           prefill_chunk=4, block_size=4, **kw)
    for rid, p in enumerate(prompts):
        cb.submit(Request(rid=rid, prompt=list(p), max_new=6))
    return cb, {r.rid: r.out for r in cb.run()}


class TestLMWeightQuant:
    def test_matches_dequant_reference_bit_exact(self, params):
        """weight_quant="q8_0" on CPU == dense decode on the
        dequantized weights, token for token."""
        prompts = [_prompt(30 + i, 6 + i % 4) for i in range(4)]
        cb_q, out_q = _run(params, CFG, prompts, weight_quant="q8_0")
        ref_params = _dequantize_linears(cb_q.params)
        _, out_d = _run(ref_params, CFG, prompts)
        assert out_q == out_d

    def test_combined_with_quantized_kv_stays_fused(self, params):
        """The largest quantized config — Q8 weights AND Q8 KV — takes
        the fused prefill path and matches its own dequant reference."""
        prompts = [_prompt(40 + i, 7) for i in range(3)]
        cb_q, out_q = _run(params, CFG, prompts, weight_quant="q8_0",
                           quantized_kv=True)
        assert cb_q.fused_prefill is True
        assert cb_q.prefill_launches == cb_q.prefill_quanta
        ref_params = _dequantize_linears(cb_q.params)
        _, out_d = _run(ref_params, CFG, prompts, quantized_kv=True)
        assert out_q == out_d

    def test_unknown_policy_raises(self, params):
        with pytest.raises(KeyError):
            ContinuousBatcher(params, CFG, slots=1, max_len=8,
                              weight_quant="q9_9")

    def test_cost_keys_carry_weight_quant(self, params):
        cm = CostModel()
        cb = ContinuousBatcher(params, CFG, slots=1, max_len=8,
                               weight_quant="q8_0", quantized_kv=True)
        kp, kd = cm.lm_keys(cb)
        assert kp == ("lm", "t", "prefill", True, True, "q8_0")
        assert kd == ("lm", "t", "decode", True, "q8_0")
        plain = ContinuousBatcher(params, CFG, slots=1, max_len=8)
        assert cm.lm_keys(plain)[0] == ("lm", "t", "prefill", True,
                                        False, None)

    def test_weights_actually_quantized(self, params):
        cb = ContinuousBatcher(params, CFG, slots=1, max_len=8,
                               weight_quant="q8_0")
        quantized = [l for l in jax.tree.leaves(
            cb.params, is_leaf=lambda x: isinstance(x, Linear))
            if isinstance(l, Linear)
            and isinstance(l.w, quant.Q8_0Tensor)]
        assert quantized, "no Linear was quantized by the policy"


class TestDiffusionWeightQuant:
    @pytest.fixture(scope="class")
    def sd_params(self):
        return init_pipeline(jax.random.PRNGKey(0), TINY_SD)

    def test_engine_runs_and_keys_carry_weight_quant(self, sd_params):
        eng = DiffusionEngine(sd_params, TINY_SD, max_batch=1,
                              weight_quant="q8_0",
                              cost_model=CostModel())
        toks = [int(t) for t in np.random.default_rng(0).integers(
            0, 256, 77)]
        eng.submit(GenerateRequest(rid=0, tokens=toks, sampler="ddim",
                                   steps=1))
        res = eng.run()
        assert len(res) == 1 and res[0].rid == 0
        assert np.isfinite(np.asarray(res[0].image,
                                      np.float32)).all()
        # The observed fused-program key carries the policy name.
        keys = list(eng.cost_model._counts) or list(
            eng.cost_model._costs)
        assert all(k[-1] == "q8_0" for k in keys if k[0] == "diff")

    def test_matches_dequant_reference(self, sd_params):
        """Quantized engine vs dense engine on dequantized weights.

        Not bit-exact like the LM path: the UNet feeds 4-D activations,
        which the dense path contracts with lead dims in place while
        ``q8_matmul_ref`` flattens to (M, K) first — XLA's f32
        accumulation order differs between the two shapes, and the
        delta compounds through bf16 casts over the whole pipeline.
        Image-level tolerance is the gate here."""
        toks = [int(t) for t in np.random.default_rng(1).integers(
            0, 256, 77)]
        eng_q = DiffusionEngine(sd_params, TINY_SD, max_batch=1,
                                weight_quant="q8_0")
        eng_q.submit(GenerateRequest(rid=0, tokens=list(toks),
                                     sampler="ddim", steps=1, seed=7))
        img_q = np.asarray(eng_q.run()[0].image, np.float32)
        eng_d = DiffusionEngine(_dequantize_linears(eng_q.params),
                                TINY_SD, max_batch=1)
        eng_d.submit(GenerateRequest(rid=0, tokens=list(toks),
                                     sampler="ddim", steps=1, seed=7))
        img_d = np.asarray(eng_d.run()[0].image, np.float32)
        np.testing.assert_allclose(img_q, img_d, atol=5e-2)
        assert float(np.abs(img_q - img_d).mean()) < 1e-2

    def test_unknown_policy_raises(self, sd_params):
        with pytest.raises(KeyError):
            DiffusionEngine(sd_params, TINY_SD, weight_quant="nope")
